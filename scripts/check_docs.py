"""Docs reference checker: every internal link and referenced module
path in ``docs/*.md`` (plus ``README.md`` and ``ROADMAP.md``) must
resolve.

Checked, per file:

- markdown links ``[text](target)`` whose target is not an external URL:
  the target (fragment stripped) must exist relative to the file;
- inline-code path references like ``src/repro/destinations/schedule.py``
  or ``benchmarks/fig_capacity.py`` (root-relative, brace groups like
  ``src/repro/{models,kernels}`` expanded): every expansion must exist;
- inline-code dotted module references like ``repro.offload.spec`` or
  ``benchmarks.run``: must map to a module file. A dotted name whose
  PREFIX maps to a module is accepted as an attribute reference (e.g.
  ``repro.destinations.REGISTRIES``) — attributes can't be verified
  without importing, and importing docs-referenced modules here would
  drag jax into the checker;
- ``python -m <module>`` invocations inside fenced code blocks: the
  module must resolve the same way.

Exit 0 when clean; exit 1 listing every dangling reference (the CI fast
tier runs this, and tests/test_docs.py runs it as a pytest).

  python scripts/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

REPO = Path(__file__).resolve().parent.parent

_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_INLINE = re.compile(r"`([^`\n]+)`")
_DASH_M = re.compile(r"-m\s+((?:repro|benchmarks|scripts|tests)(?:\.\w+)+"
                     r"|repro\.\w+|benchmarks\.\w+)")
_PATHLIKE = re.compile(r"^(?:src|docs|benchmarks|scripts|tests|examples)/"
                       r"[\w./{},-]*$")
_MODLIKE = re.compile(r"^(?:repro|benchmarks|scripts|tests)(?:\.\w+)+$")


def _expand_braces(token: str) -> List[str]:
    m = re.search(r"\{([^{}]+)\}", token)
    if not m:
        return [token]
    out = []
    for alt in m.group(1).split(","):
        out += _expand_braces(token[:m.start()] + alt + token[m.end():])
    return out


def _module_exists(dotted: str) -> bool:
    parts = dotted.split(".")
    base = REPO / "src" if parts[0] == "repro" else REPO
    stem = base.joinpath(*parts)
    return stem.with_suffix(".py").is_file() or \
        (stem / "__init__.py").is_file()


def _module_or_attr_exists(dotted: str) -> bool:
    """True when the dotted name, or any prefix of it, is a module —
    the remainder is then an (unverifiable) attribute reference."""
    parts = dotted.split(".")
    return any(_module_exists(".".join(parts[:i]))
               for i in range(len(parts), 0, -1))


def check_file(path: Path) -> List[str]:
    text = path.read_text(encoding="utf-8")
    try:
        rel = path.relative_to(REPO)
    except ValueError:  # a file outside the repo (tests use tmp dirs)
        rel = path
    errors: List[str] = []

    # markdown links (external schemes skipped)
    for target in _LINK.findall(text):
        if "://" in target or target.startswith("mailto:"):
            continue
        local = target.split("#", 1)[0]
        if not local:
            continue  # same-file fragment
        if not (path.parent / local).exists():
            errors.append(f"{rel}: dangling link target {target!r}")

    prose = _FENCE.sub("", text)
    for token in _INLINE.findall(prose):
        token = token.strip()
        if _PATHLIKE.match(token):
            for variant in _expand_braces(token):
                if not (REPO / variant.rstrip("/")).exists():
                    errors.append(
                        f"{rel}: referenced path {variant!r} does not exist"
                    )
        elif _MODLIKE.match(token):
            if not _module_or_attr_exists(token):
                errors.append(
                    f"{rel}: referenced module {token!r} does not resolve"
                )

    for dotted in _DASH_M.findall(text):
        if not _module_exists(dotted):
            errors.append(
                f"{rel}: `-m {dotted}` does not resolve to a module"
            )
    return errors


def checked_files() -> List[Path]:
    """Every file the checker covers: the docs suite, the README, and
    the ROADMAP (whose references to repo paths drift just as easily)."""
    return sorted((REPO / "docs").glob("*.md")) + [
        REPO / "README.md", REPO / "ROADMAP.md"
    ]


def check_all() -> List[str]:
    errors: List[str] = []
    for f in checked_files():
        errors += check_file(f)
    return errors


def main() -> int:
    errors = check_all()
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    print(f"check_docs: {len(checked_files())} files, "
          f"{len(errors)} dangling reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
