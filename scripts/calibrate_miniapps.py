"""Calibrate the HardwareModel constants against the paper's fig. 5.

Targets (measured by the paper on i5-7500 + Quadro P4000, PGI 19.4):
  Himeno  previous [33]  4.8x   proposed 15.4x
  NAS.FT  previous [33]  5.4x   proposed 10.0x

Free constants: cpu_flops, cpu_membw, accel_flops_kernels, accel_membw,
link_bw (accel_flops_parallel = 0.8 * kernels, vector = kernels / 15 fixed
ratios). Each candidate is scored by running the REAL GA (fixed seed) for
both apps and both methods — the same pipeline the benchmarks use — and
minimizing the sum of squared log-errors to the four targets.

Run: PYTHONPATH=src python scripts/calibrate_miniapps.py [--workers N]
Prints the best constants; they are then frozen into core/evaluator.py.

Each candidate's four GA runs drive the ``repro.offload`` facade (each
is one analyze+search pipeline with the candidate HardwareModel injected
— candidates aren't in the registry): --workers measures individuals
concurrently, and --cache-dir persists every (hardware fingerprint,
genome) measurement so an interrupted sweep resumes warm — re-scored
grid points are answered entirely from cache.
"""
import argparse
import itertools
import math
import os
import sys

import numpy as np

from repro.core import evaluator as ev
from repro.offload import Offloader, OffloadSpec

TARGETS = {("himeno", "prev"): 4.8, ("himeno", "prop"): 15.4,
           ("nasft", "prev"): 5.4, ("nasft", "prop"): 10.0}

METHOD_OF = {"prev": "previous", "prop": "proposed"}


def make_hw(cpu_f, cpu_bw, acc_f, acc_bw, link):
    # the name keys the fitness cache (via MiniappEvaluator.fingerprint),
    # so it must identify this candidate's constants uniquely
    return ev.HardwareModel(
        name=f"cand-{cpu_f:.4g}-{cpu_bw:.4g}-{acc_f:.4g}-{acc_bw:.4g}"
             f"-{link:.4g}",
        cpu_flops=cpu_f,
        cpu_membw=cpu_bw,
        accel_flops_kernels=acc_f,
        accel_flops_parallel=0.8 * acc_f,
        accel_flops_vector=acc_f / 15.0,
        accel_membw=acc_bw,
        link_bw=link,
        link_latency=2.0e-5,
        launch_latency=8.0e-6,
    )


def speedups(hw, workers: int = 1, cache_dir: str = None):
    out = {}
    for name in ("himeno", "nasft"):
        for method in ("prev", "prop"):
            # one cache file PER candidate (hw.name encodes the
            # constants): a shared file would be re-parsed in full by
            # every new candidate only to discard foreign-fingerprint
            # lines — O(candidates^2) JSON work by sweep end
            cache = os.path.join(
                cache_dir, f"{name}-{method}-{hw.name}.jsonl"
            ) if cache_dir else None
            spec = OffloadSpec(program=name, mode="binary",
                               method=METHOD_OF[method], seed=0,
                               workers=workers, cache=cache)
            res = Offloader(spec, hw=hw).run(until="search")
            out[(name, method)] = res.speedup
    return out


def score(sp):
    return sum(math.log(sp[k] / TARGETS[k]) ** 2 for k in TARGETS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=1,
                    help="concurrent measurements per GA generation")
    ap.add_argument("--cache-dir", default=None,
                    help="persist fitness measurements (JSONL per "
                         "app/method); an interrupted sweep resumes warm")
    args = ap.parse_args()
    if args.cache_dir:
        os.makedirs(args.cache_dir, exist_ok=True)

    def run_speedups(hw):
        return speedups(hw, workers=args.workers, cache_dir=args.cache_dir)

    grid = {
        "cpu_f": [2.0e9, 3.0e9, 4.5e9],
        "cpu_bw": [6.0e9, 9.0e9, 13e9],
        "acc_f": [3e11, 6e11, 9e11],
        "acc_bw": [6e10, 1.0e11, 1.6e11],
        "link": [4e9, 6e9, 9e9],
    }
    best = None
    for vals in itertools.product(*grid.values()):
        hw = make_hw(*vals)
        sp = run_speedups(hw)
        s = score(sp)
        if best is None or s < best[0]:
            best = (s, vals, sp)
            print(f"score={s:.4f} {dict(zip(grid, vals))}")
            print("  " + " ".join(f"{k[0]}/{k[1]}={v:.1f}x" for k, v in sp.items()))
            sys.stdout.flush()
    # local refinement around the best grid point
    s0, vals0, _ = best
    rng = np.random.default_rng(0)
    cur = np.array(vals0, dtype=float)
    cur_s = s0
    for it in range(60):
        cand = cur * np.exp(rng.normal(0, 0.15, size=cur.shape))
        sp = run_speedups(make_hw(*cand))
        s = score(sp)
        if s < cur_s:
            cur, cur_s = cand, s
            print(f"refine[{it}] score={s:.4f} "
                  + " ".join(f"{v:.3g}" for v in cand))
            print("  " + " ".join(f"{k[0]}/{k[1]}={v:.1f}x" for k, v in sp.items()))
            sys.stdout.flush()
    print("\nFINAL:", " ".join(f"{v:.4g}" for v in cur), "score", cur_s)
    print(run_speedups(make_hw(*cur)))


if __name__ == "__main__":
    main()
