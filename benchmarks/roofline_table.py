"""Aggregate the dry-run JSON records into the EXPERIMENTS.md roofline table.

Reads experiments/dryrun/*.json (produced by ``repro.launch.dryrun --all``)
and prints the per-cell three-term roofline, bottleneck, useful-FLOPs ratio
and roofline fraction; optionally as a markdown table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str, tag: str = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if tag and rec.get("tag") != tag:
            continue
        recs.append(rec)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 / 2x16x16")
    args = ap.parse_args(argv)

    recs = load(args.dir, args.tag)
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    if not recs:
        print(f"no dry-run records in {args.dir} (run repro.launch.dryrun)")
        return

    sep = "|" if args.markdown else " "
    hdr = ["arch", "shape", "mesh", "t_comp", "t_mem", "t_coll", "t_step",
           "bound", "useful", "roofline%", "GiB/dev"]
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{'arch':26s} {'shape':12s} {'mesh':8s} {'t_comp':>8s} "
              f"{'t_mem':>8s} {'t_coll':>8s} {'t_step':>8s} {'bound':>10s} "
              f"{'useful':>7s} {'roofl%':>7s} {'GiB/dev':>8s}")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r["roofline"]
        peak = r["memory"]["peak_bytes_per_device"] / 2**30
        cells = [
            r["arch"], r["shape"], r["mesh"],
            fmt_s(rl["t_compute_s"]), fmt_s(rl["t_memory_s"]),
            fmt_s(rl["t_collective_s"]), fmt_s(rl["t_step_s"]),
            rl["bottleneck"], f"{rl['useful_flops_ratio']:.2f}",
            f"{rl['roofline_fraction']*100:.1f}%", f"{peak:.2f}",
        ]
        if args.markdown:
            print("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            print(f"{cells[0]:26s} {cells[1]:12s} {cells[2]:8s} "
                  f"{cells[3]:>8s} {cells[4]:>8s} {cells[5]:>8s} "
                  f"{cells[6]:>8s} {cells[7]:>10s} {cells[8]:>7s} "
                  f"{cells[9]:>7s} {cells[10]:>8s}")


if __name__ == "__main__":
    main()
