"""Kernel micro-benchmarks: Pallas kernels (interpret) vs jnp references.

On this CPU container the interesting output is CORRECTNESS deltas and the
reference-path wall times (the TPU numbers come from the dry-run roofline);
interpret=True wall-clock is not meaningful and is skipped by default.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.evalpool import parallel_map
from repro.kernels import ops, ref


def _time(fn, *args, repeats=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
            out,
        )
        best = min(best, time.perf_counter() - t0)
    return best


def bench_attention(check_kernel: bool, workers: int = 1):
    print("\n== flash attention ==")
    rng = np.random.default_rng(0)
    cases = []
    # wall-clock timings run serially (parallel timing is meaningless);
    # only the interpret-mode correctness checks below fan out
    for (B, S, H, K, D) in [(1, 512, 8, 8, 64), (1, 1024, 8, 2, 64),
                            (4, 512, 16, 2, 128)]:
        q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
        f_ref = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
        f_chk = jax.jit(
            lambda q, k, v: ref.attention_chunked(q, k, v, causal=True)
        )
        t_ref = _time(f_ref, q, k, v)
        t_chk = _time(f_chk, q, k, v)
        err = float(
            jnp.abs(f_ref(q, k, v) - f_chk(q, k, v)).max()
        )
        cases.append(((B, S, H, K, D), (q, k, v), f_ref, t_ref, t_chk, err))

    def check(case):
        (_, (q, k, v), f_ref, *_rest) = case
        out_k = ops.flash_attention(q, k, v, causal=True, interpret=True)
        return float(jnp.abs(f_ref(q, k, v) - out_k).max())

    errs_k = parallel_map(check, cases, workers) if check_kernel else None
    for i, ((B, S, H, K, D), _, _, t_ref, t_chk, err) in enumerate(cases):
        line = (f"B{B} S{S} H{H}/K{K} D{D}: dense {t_ref*1e3:7.1f} ms, "
                f"chunked {t_chk*1e3:7.1f} ms, |err| {err:.2e}")
        if errs_k is not None:
            line += f", pallas(interp) |err| {errs_k[i]:.2e}"
        print("  " + line)
        print(f"csv:attention,{B},{S},{H},{K},{D},{t_ref*1e6:.0f},{t_chk*1e6:.0f},{err:.2e}")


def bench_ssd(check_kernel: bool, workers: int = 1):
    print("\n== SSD chunked scan ==")
    rng = np.random.default_rng(0)
    cases = []
    for (B, S, H, P, N, chunk) in [(1, 1024, 8, 64, 64, 128),
                                   (4, 512, 8, 64, 128, 128)]:
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(B, S, H)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(H,)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        f = jax.jit(lambda *a: ref.ssd_ref(*a, chunk=chunk))
        t = _time(f, x, dt, A, Bm, Cm)
        cases.append(((B, S, H, P, N, chunk), (x, dt, A, Bm, Cm), f, t))

    def check(case):
        ((_, _, _, _, _, chunk), args_, f, _) = case
        out_k = ops.ssd_scan(*args_, chunk=chunk, interpret=True)
        return float(jnp.abs(f(*args_) - out_k).max())

    errs_k = parallel_map(check, cases, workers) if check_kernel else None
    for i, ((B, S, H, P, N, chunk), _, _, t) in enumerate(cases):
        line = f"B{B} S{S} H{H} P{P} N{N} chunk{chunk}: ref {t*1e3:7.1f} ms"
        if errs_k is not None:
            line += f", pallas(interp) |err| {errs_k[i]:.2e}"
        print("  " + line)
        print(f"csv:ssd,{B},{S},{H},{P},{N},{t*1e6:.0f}")


def main(argv=None):
    from benchmarks.common import add_common_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--check-kernel", action="store_true",
                    help="also run the Pallas kernels in interpret mode")
    add_common_args(ap, seed=False, cache=False, smoke=False)
    args = ap.parse_args(argv)
    bench_attention(args.check_kernel, args.workers)
    bench_ssd(args.check_kernel, args.workers)


if __name__ == "__main__":
    main()
