"""Search-quality figure: is the GA's answer trustworthy, and does
fitness sharing buy anything (docs/observability.md)?

Two sections, both on the modeled pipeline (cheap, deterministic):

1. **Stability + rank fidelity** — the full pipeline per program with
   the report-stage quality metrics on: pass@k winner stability across
   GA seeds (window, spread, distinct winners) and, where a measured
   reference exists, the modeled-vs-measured rank correlation
   (spearman / kendall, via ``ga.rank_probe``).

2. **Diversity ablation** — the same searches with fitness sharing
   (``ga.diversity``) off vs on: winner time, stability spread, and
   final-population allele entropy side by side. Diversity trades a
   little convergence speed for selection pressure spread over distinct
   genomes; this table is where that trade is visible.

  PYTHONPATH=src python -m benchmarks.fig_quality
  PYTHONPATH=src python -m benchmarks.fig_quality --smoke --diversity 1.0
"""
from __future__ import annotations

import argparse
from typing import List, Optional

from benchmarks.common import add_common_args
from repro.offload import GAControls, Offloader, OffloadSpec
from repro.offload.quality import allele_entropy


def _spec(program: str, args, *, diversity: float = 0.0,
          rank_probe: bool = False) -> OffloadSpec:
    kw = dict(
        program=program,
        mode="binary",
        seed=args.seed,
        workers=args.workers,
        cache=args.cache,
        ga=GAControls(diversity=diversity, stability_seeds=args.k,
                      stability_window=args.window,
                      rank_probe=rank_probe),
    )
    if args.smoke:
        kw.update(population=6, generations=4)
    return OffloadSpec(**kw)


def _quality(spec: OffloadSpec):
    res = Offloader(spec).run()
    rep = res.stage("report").payload["quality"]
    search = res.stage("search").payload
    pop = [tuple(g) for g in search["final_population"]]
    alleles = max(2, len(search["ga"].get("allele_names", ())) or 2)
    return res, rep, allele_entropy(pop, alleles)


def _stability_line(st: dict) -> str:
    if "skipped" in st:
        return f"stability skipped ({st['skipped']})"
    return (f"pass@{st['k']} {st['pass_at_k']:.0%} "
            f"(window {st['window']:.1%}, spread +{st['rel_spread']:.1%}, "
            f"{st['distinct_winners']} distinct winner(s))")


def _rank_line(rk: dict) -> str:
    if "skipped" in rk:
        return f"rank skipped ({rk['skipped']})"
    if rk.get("spearman") is None:
        return f"rank undefined ({rk.get('note', 'constant side')})"
    kend = "n/a" if rk.get("kendall") is None else f"{rk['kendall']:+.2f}"
    return (f"spearman {rk['spearman']:+.2f} / kendall {kend} "
            f"over {rk['n']} candidates vs {rk['reference']}")


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    add_common_args(ap)
    ap.add_argument("--programs", default="himeno,nasft",
                    help="comma-separated miniapps")
    ap.add_argument("--k", type=int, default=3,
                    help="stability seeds (pass@k)")
    ap.add_argument("--window", type=float, default=0.02,
                    help="stability window (relative)")
    ap.add_argument("--diversity", type=float, default=1.0,
                    help="fitness-sharing exponent for the ablation's "
                         "ON arm")
    args = ap.parse_args(argv)
    programs = [p.strip() for p in args.programs.split(",") if p.strip()]

    print("\n== search quality: winner stability + rank fidelity ==")
    for prog in programs:
        res, rep, _ = _quality(_spec(prog, args, rank_probe=True))
        print(f"  {prog:8s} best {res.best_time_s:.4f}s "
              f"(speedup {res.speedup:.2f}x)")
        print(f"           {_stability_line(rep['stability'])}")
        print(f"           {_rank_line(rep['rank'])}")

    print(f"\n== diversity ablation: ga.diversity 0.0 vs "
          f"{args.diversity} ==")
    print("csv:program,diversity,best_time_s,rel_spread,entropy")
    for prog in programs:
        for div in (0.0, args.diversity):
            res, rep, ent = _quality(_spec(prog, args, diversity=div))
            st = rep["stability"]
            spread = st.get("rel_spread")
            spread_s = "n/a" if spread is None else f"+{spread:.1%}"
            print(f"  {prog:8s} diversity={div:<4g} "
                  f"best {res.best_time_s:.4f}s  spread {spread_s}  "
                  f"final-pop allele entropy {ent:.3f}")
            print(f"csv:{prog},{div:g},{res.best_time_s:.6f},"
                  f"{'' if spread is None else f'{spread:.6f}'},"
                  f"{ent:.4f}")


if __name__ == "__main__":
    main()
