"""Benchmark driver: one section per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run            # everything fast
  PYTHONPATH=src python -m benchmarks.run --section fig5 --ablate
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig4_convergence,
    fig5_speedup,
    kernel_bench,
    roofline_table,
    transfer_ablation,
)

SECTIONS = {
    "fig4": lambda args: fig4_convergence.main([]),
    "fig5": lambda args: fig5_speedup.main(
        ["--ablate"] if args.ablate else []
    ),
    "transfer": lambda args: transfer_ablation.main([]),
    "kernels": lambda args: kernel_bench.main(
        ["--check-kernel"] if args.check_kernel else []
    ),
    "roofline": lambda args: roofline_table.main([]),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=list(SECTIONS), default=None)
    ap.add_argument("--ablate", action="store_true")
    ap.add_argument("--check-kernel", action="store_true")
    args = ap.parse_args()

    picks = [args.section] if args.section else list(SECTIONS)
    t0 = time.time()
    for name in picks:
        print(f"\n{'='*72}\n== benchmark section: {name}\n{'='*72}")
        sys.stdout.flush()
        SECTIONS[name](args)
    print(f"\n[benchmarks] all sections done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
