"""Benchmark driver: one section per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run            # everything fast
  PYTHONPATH=src python -m benchmarks.run --section fig5 --ablate
  PYTHONPATH=src python -m benchmarks.run --section evalpool --workers 8
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig4_convergence,
    fig5_speedup,
    fig_capacity,
    fig_fidelity,
    fig_mixed_destinations,
    kernel_bench,
    roofline_table,
    transfer_ablation,
)


def _evalpool_section(args) -> None:
    """Pooled vs serial generation wall-clock on a latency-instrumented
    evaluator: the analytic miniapp model with a fixed sleep injected per
    measurement (standing in for a verification-environment deploy+run)."""
    from repro.core import evalpool as ep
    from repro.core import evaluator as ev
    from repro.core import ga, miniapps
    from repro.core import transfer as tr

    delay_s = 0.02
    prog = miniapps.himeno_program()
    base = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)

    def slow_eval(genes):
        time.sleep(delay_s)
        return base(genes)

    n = prog.gene_length
    params = ga.GAParams.for_gene_length(n, seed=0)
    print(f"\n== evalpool: {params.population}x{params.generations} GA, "
          f"{delay_s*1e3:.0f} ms per measurement ==")
    print("csv:workers,wall_s,evals,cache_hits,hit_rate,best_time_s")
    serial_wall = None
    for workers in (1, args.workers) if args.workers > 1 else (1, 4):
        with ep.EvalPool(slow_eval, workers=workers) as pool:
            r = ga.run_ga(None, n, params, pool=pool)
            tot = pool.totals()
        if serial_wall is None:
            serial_wall = r.wall_s
        print(f"  workers={workers}: wall {r.wall_s:6.2f}s "
              f"({serial_wall / r.wall_s:4.1f}x vs serial), "
              f"{tot.evaluated} measurements, {tot.cache_hits} cache hits "
              f"(hit-rate {tot.hit_rate:.0%}), best {r.best_time_s:.3f}s")
        print(f"csv:{workers},{r.wall_s:.3f},{tot.evaluated},"
              f"{tot.cache_hits},{tot.hit_rate:.3f},{r.best_time_s:.4f}")


SECTIONS = {
    "fig4": lambda args: fig4_convergence.main(
        ["--workers", str(args.workers)]
    ),
    "fig5": lambda args: fig5_speedup.main(
        (["--ablate"] if args.ablate else [])
        + ["--workers", str(args.workers)]
    ),
    "transfer": lambda args: transfer_ablation.main([]),
    "kernels": lambda args: kernel_bench.main(
        (["--check-kernel"] if args.check_kernel else [])
        + ["--workers", str(args.workers)]
    ),
    "roofline": lambda args: roofline_table.main([]),
    "evalpool": _evalpool_section,
    "mixed": lambda args: fig_mixed_destinations.main(
        ["--workers", str(args.workers)]
    ),
    "capacity": lambda args: fig_capacity.main(
        ["--workers", str(args.workers)]
    ),
    # calibration probes + calibrated search; --smoke adds the
    # subprocess measured-search section too (tiny budget)
    "fidelity": lambda args: fig_fidelity.main(
        ["--workers", str(args.workers), "--smoke"]
    ),
}


def main() -> None:
    from benchmarks.common import add_common_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=list(SECTIONS), default=None)
    ap.add_argument("--ablate", action="store_true")
    ap.add_argument("--check-kernel", action="store_true")
    add_common_args(ap, seed=False, cache=False, smoke=False)
    args = ap.parse_args()

    picks = [args.section] if args.section else list(SECTIONS)
    t0 = time.time()
    for name in picks:
        print(f"\n{'='*72}\n== benchmark section: {name}\n{'='*72}")
        sys.stdout.flush()
        SECTIONS[name](args)
    print(f"\n[benchmarks] all sections done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
