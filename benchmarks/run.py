"""Benchmark driver: one section per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run            # everything fast
  PYTHONPATH=src python -m benchmarks.run --section fig5 --ablate
  PYTHONPATH=src python -m benchmarks.run --section evalpool --workers 8
  PYTHONPATH=src python -m benchmarks.run --section sweep
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig4_convergence,
    fig5_speedup,
    fig_async,
    fig_blocks,
    fig_capacity,
    fig_fidelity,
    fig_mixed_destinations,
    fig_quality,
    kernel_bench,
    roofline_table,
    transfer_ablation,
)


def _forward(args, *, workers=True, cache=True, smoke=True) -> list:
    """Render the shared flags (benchmarks.common.add_common_args) back
    into an argv for a section that accepts them."""
    argv = []
    if workers:
        argv += ["--workers", str(args.workers)]
    if cache and args.cache:
        argv += ["--cache", args.cache]
    if smoke and args.smoke:
        argv += ["--smoke"]
    return argv


def _evalpool_section(args) -> None:
    """Pooled vs serial generation wall-clock on a latency-instrumented
    evaluator: the analytic miniapp model with a fixed sleep injected per
    measurement (standing in for a verification-environment deploy+run)."""
    from repro.core import evalpool as ep
    from repro.core import evaluator as ev
    from repro.core import ga, miniapps
    from repro.core import transfer as tr

    delay_s = 0.02
    prog = miniapps.himeno_program()
    base = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)

    def slow_eval(genes):
        time.sleep(delay_s)
        return base(genes)

    n = prog.gene_length
    params = ga.GAParams.for_gene_length(n, seed=0)
    print(f"\n== evalpool: {params.population}x{params.generations} GA, "
          f"{delay_s*1e3:.0f} ms per measurement ==")
    print("csv:workers,wall_s,evals,cache_hits,hit_rate,best_time_s")
    serial_wall = None
    for workers in (1, args.workers) if args.workers > 1 else (1, 4):
        with ep.EvalPool(slow_eval, workers=workers) as pool:
            r = ga.run_ga(None, n, params, pool=pool)
            tot = pool.totals()
        if serial_wall is None:
            serial_wall = r.wall_s
        print(f"  workers={workers}: wall {r.wall_s:6.2f}s "
              f"({serial_wall / r.wall_s:4.1f}x vs serial), "
              f"{tot.evaluated} measurements, {tot.cache_hits} cache hits "
              f"(hit-rate {tot.hit_rate:.0%}), best {r.best_time_s:.3f}s")
        print(f"csv:{workers},{r.wall_s:.3f},{tot.evaluated},"
              f"{tot.cache_hits},{tot.hit_rate:.3f},{r.best_time_s:.4f}")


def _sweep_section(args) -> None:
    """The model-zoo sweep driver (docs/benchmarks.md) at the smoke
    budget: the fixed 3-cell matrix through the full pipeline, one
    trajectory point + leaderboard into a scratch file — the committed
    BENCH_sweep.json is never touched from here."""
    import tempfile

    from repro.offload.__main__ import main as offload_main

    with tempfile.TemporaryDirectory(prefix="bench-sweep-") as tmp:
        argv = ["sweep", "--smoke",
                "--dir", f"{tmp}/cells",
                "--out", f"{tmp}/BENCH_sweep.json",
                ] + _forward(args, smoke=False)
        rc = offload_main(argv)
        if rc:
            raise SystemExit(rc)


def _blocks_section(args) -> None:
    rc = fig_blocks.main(_forward(args))
    if rc:
        raise SystemExit(rc)


def _async_section(args) -> None:
    rc = fig_async.main(_forward(args, cache=False))
    if rc:
        raise SystemExit(rc)


SECTIONS = {
    "fig4": lambda args: fig4_convergence.main(
        _forward(args, smoke=False)
    ),
    "fig5": lambda args: fig5_speedup.main(
        (["--ablate"] if args.ablate else [])
        + _forward(args, smoke=False)
    ),
    "transfer": lambda args: transfer_ablation.main(
        _forward(args, workers=False, cache=False)
    ),
    "kernels": lambda args: kernel_bench.main(
        (["--check-kernel"] if args.check_kernel else [])
        + _forward(args, cache=False, smoke=False)
    ),
    "roofline": lambda args: roofline_table.main([]),
    "evalpool": _evalpool_section,
    "mixed": lambda args: fig_mixed_destinations.main(
        _forward(args)
    ),
    "capacity": lambda args: fig_capacity.main(
        _forward(args)
    ),
    # calibration probes + calibrated search; --smoke adds the
    # subprocess measured-search section too (tiny budget), so the
    # driver always passes it
    "fidelity": lambda args: fig_fidelity.main(
        _forward(args, smoke=False) + ["--smoke"]
    ),
    "sweep": _sweep_section,
    # search-quality observability (docs/observability.md): pass@k
    # winner stability, rank fidelity, and the ga.diversity ablation
    "quality": lambda args: fig_quality.main(
        _forward(args)
    ),
    # function-block substitution vs the best loop-level placement
    # (docs/blocks.md); the figure's own exit code carries the verdict
    "blocks": _blocks_section,
    # fast-search substrate: batch pricing throughput (>=10x verdict in
    # the exit code) + steady-state vs generational wall-clock
    "async": _async_section,
}


def main() -> None:
    from benchmarks.common import add_common_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=list(SECTIONS), default=None)
    ap.add_argument("--ablate", action="store_true")
    ap.add_argument("--check-kernel", action="store_true")
    add_common_args(ap, seed=False)
    args = ap.parse_args()

    picks = [args.section] if args.section else list(SECTIONS)
    t0 = time.time()
    for name in picks:
        print(f"\n{'='*72}\n== benchmark section: {name}\n{'='*72}")
        sys.stdout.flush()
        SECTIONS[name](args)
    print(f"\n[benchmarks] all sections done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
