"""Fidelity figure: how honest is the model, measured on this machine.

Every other figure prices candidates with the analytic model. This one
closes the loop the paper's method actually demands (each GA individual
is compiled and *timed* on the verification machine) in three sections:

1. **Calibration** — measure the designed probe set (himeno + nasft,
   several grids, host and accelerator paths), fit per-destination
   rate/setup/transfer constants by least squares, and print the probe
   table with fit residuals: the table IS the honesty statement for the
   modeled numbers every other figure reports.

2. **Calibrated search** — the same paper-flow pipeline at
   ``fidelity="calibrated"``: the search runs under the fitted machine,
   and the report's fidelity section states the predicted-vs-measured
   ratio per destination for the winner.

3. **Measured search** (``--measured``, also in ``--smoke``) — the
   paper's real measurement loop: ``fidelity="measured"`` wall-clocks
   every unique candidate in spawn-context subprocess workers. Slowest
   and most honest; tiny budget by design (the run-fn cache key
   collapses equivalent genomes to one real measurement each).

  PYTHONPATH=src python -m benchmarks.fig_fidelity
  PYTHONPATH=src python -m benchmarks.fig_fidelity --smoke --measured
"""
from __future__ import annotations

import argparse
import os
import tempfile

from benchmarks.common import add_common_args
from repro.offload import Offloader, OffloadSpec
from repro.offload import calibrate


def _fidelity_rows(result) -> str:
    fid = result.stage("verify").payload.get("fidelity", {})
    if "rows" not in fid:
        return f"  (skipped: {fid.get('skipped', 'no fidelity section')})"
    return "\n".join(
        f"  {r['destination']:>4s} {r['placement']:16s} predicted "
        f"{r['predicted_s']:.4g}s measured {r['measured_s']:.4g}s "
        f"-> ratio {r['ratio']:.2f}x"
        if "ratio" in r else
        f"  {r['placement']:16s} skipped ({r['skipped']})"
        for r in fid["rows"]
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true",
                    help="also run the measured-fidelity search "
                         "(subprocess wall clocks; slowest section)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="wall-clock repeats per probe/individual")
    add_common_args(ap)
    args = ap.parse_args(argv)
    tmp = tempfile.mkdtemp(prefix="fig-fidelity-")

    # 1) calibration: probes, fit, residuals
    cal = calibrate.run_calibration(base="quadro-p4000",
                                    repeats=args.repeats)
    calibrate.install(cal)
    print(f"== calibration: quadro-p4000 -> {cal.name} on {cal.host} ==")
    print("csv:app,dest,grid,steps,measured_s,fitted_s,rel_err")
    for p in cal.probes:
        grid = "x".join(map(str, p["grid"]))
        print(f"  {p['app']:7s} {p['dest']:5s} {grid:>10s} x{p['steps']}: "
              f"measured {p['measured_s']:.4g}s fitted {p['fitted_s']:.4g}s "
              f"({p['rel_err']:+.1%})")
        print(f"csv:{p['app']},{p['dest']},{grid},{p['steps']},"
              f"{p['measured_s']:.6g},{p['fitted_s']:.6g},"
              f"{p['rel_err']:.4f}")
    r = cal.residuals()
    base = dict(cpu_flops=3.262e9, accel_flops_kernels=4.988e11)
    print(f"residuals: max |{r['max_abs_rel']:.1%}| mean "
          f"|{r['mean_abs_rel']:.1%}| over {r['n']} probes; "
          f"pinned: {', '.join(cal.pinned)}")
    print("fitted vs frozen: cpu "
          f"{cal.constants['cpu_flops']:.3g} vs {base['cpu_flops']:.3g} "
          f"flop/s, accel {cal.constants['accel_flops_kernels']:.3g} vs "
          f"{base['accel_flops_kernels']:.3g} flop/s (this container's "
          "numpy/XLA-CPU paths, not the paper's P4000 — divergence "
          "expected and now *quantified*)")

    # 2) calibrated pipeline: search under the fitted machine (the
    # section-1 calibration is injected — probes are measured ONCE)
    budget = dict(population=6, generations=4) if args.smoke else {}
    for app in ("himeno",) if args.smoke else ("himeno", "nasft"):
        spec = OffloadSpec(program=app, fidelity="calibrated",
                           repeats=args.repeats, seed=args.seed,
                           workers=args.workers, cache=args.cache,
                           **budget)
        res = Offloader(
            spec, artifact_path=os.path.join(tmp, f"{app}-cal.json"),
            calibration=cal,
        ).run()
        print(f"\n== calibrated search: {app} ==")
        print(f"  winner {res.best_time_s:.4g}s, speedup "
              f"{res.speedup:.1f}x over all-host (both under the "
              "calibrated machine)")
        print(_fidelity_rows(res))
        fid = res.stage("verify").payload["fidelity"]
        print("csv:calibrated," + app + ","
              + ",".join(f"{r['ratio']:.4f}" for r in fid["rows"]))

    # 3) measured pipeline: real subprocess wall clocks
    if args.measured or args.smoke:
        spec = OffloadSpec(program="himeno", fidelity="measured",
                           executor="process", workers=max(2, args.workers),
                           repeats=args.repeats, population=4,
                           generations=2, seed=args.seed,
                           cache=os.path.join(tmp, "measured.jsonl"))
        res = Offloader(
            spec, artifact_path=os.path.join(tmp, "himeno-meas.json")
        ).run()
        p = res.stage("search").payload
        print("\n== measured search: himeno (subprocess wall clocks) ==")
        print(f"  winner {res.best_time_s:.4g}s from "
              f"{p['evaluations']} real measurements "
              f"({p['cache_hits']} cache hits)")
        print(_fidelity_rows(res))
        fid = res.stage("verify").payload["fidelity"]
        print("csv:measured,himeno,"
              + ",".join(f"{r['ratio']:.4f}" for r in fid["rows"]))


if __name__ == "__main__":
    main()
