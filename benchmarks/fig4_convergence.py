"""Fig. 4 reproduction: GA generations vs best performance (NAS.FT).

The paper's fig. 4 plots each generation's best performance for NAS.FT
under the previous method [33], converging from CPU-only 31.3 s to 5.8 s
(5.4x) over 20 generations. This benchmark emits the same curve for both
the previous and proposed configurations, driving the ``repro.offload``
facade's analyze+search stages, as speedup-vs-CPU per generation
(ASCII plot + CSV).
"""
from __future__ import annotations

import argparse

from benchmarks.common import add_common_args
from repro.core import miniapps
from repro.offload import Offloader, OffloadSpec


def convergence(app: str, method: str, seed: int = 0, workers: int = 1,
                cache: str = None):
    spec = OffloadSpec(program=app, mode="binary", method=method,
                       seed=seed, workers=workers, cache=cache)
    res = Offloader(spec).run(until="search")
    return res.baseline_time_s, res.stage("search").payload


def ascii_plot(rows, width: int = 50):
    m = max(r[1] for r in rows)
    out = []
    for gen, sp in rows:
        bar = "#" * int(width * sp / m)
        out.append(f"  gen {gen:2d} | {bar} {sp:.2f}x")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="nasft", choices=list(miniapps.MINIAPPS))
    add_common_args(ap, smoke=False)
    args = ap.parse_args(argv)

    print(f"== fig4: GA convergence, {args.app} ==")
    for method in ("previous", "proposed"):
        cpu, search = convergence(args.app, method, args.seed, args.workers,
                                  args.cache)
        history = search["history"]
        rows = [
            (h["generation"], cpu / h["best_time_s"]) for h in history
        ]
        dedup = max((h["dedup_ratio"] for h in history), default=0.0)
        best = search["best_time_s"]
        print(f"\n[{method}] CPU-only {cpu:.1f}s; "
              f"final {best:.2f}s = {cpu/best:.1f}x "
              f"({search['evaluations']} evals, {search['cache_hits']} "
              f"cache hits, peak dedup {dedup:.0%}, "
              f"search wall {search['wall_s']:.1f}s)")
        print(ascii_plot(rows))
        print("csv:generation,speedup,gen_wall_s,hit_rate")
        for (g, s), h in zip(rows, history):
            print(f"csv:{g},{s:.3f},{h['gen_wall_s']:.4f},"
                  f"{h['hit_rate']:.3f}")


if __name__ == "__main__":
    main()
