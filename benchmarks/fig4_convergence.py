"""Fig. 4 reproduction: GA generations vs best performance (NAS.FT).

The paper's fig. 4 plots each generation's best performance for NAS.FT
under the previous method [33], converging from CPU-only 31.3 s to 5.8 s
(5.4x) over 20 generations. This benchmark emits the same curve for both
the previous and proposed configurations from the analytic verification
environment, as speedup-vs-CPU per generation (ASCII plot + CSV).
"""
from __future__ import annotations

import argparse

from repro.core import evaluator as ev
from repro.core import evalpool as ep
from repro.core import ga, miniapps
from repro.core import transfer as tr


def convergence(app: str, method: str, seed: int = 0, workers: int = 1):
    prog = miniapps.MINIAPPS[app]()
    n = prog.gene_length
    cpu = ev.predict_time(prog, (0,) * n).total_s
    if method == "previous":
        e = ev.MiniappEvaluator(
            prog, tr.TransferMode.NEST, staged=False, kernels_only=True
        )
    else:
        e = ev.MiniappEvaluator(prog, tr.TransferMode.BULK, staged=True)
    params = ga.GAParams.for_gene_length(n, seed=seed)
    with ep.EvalPool(e, workers=workers) as pool:
        result = ga.run_ga(None, n, params, pool=pool)
    return cpu, result


def ascii_plot(rows, width: int = 50):
    m = max(r[1] for r in rows)
    out = []
    for gen, sp in rows:
        bar = "#" * int(width * sp / m)
        out.append(f"  gen {gen:2d} | {bar} {sp:.2f}x")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="nasft", choices=list(miniapps.MINIAPPS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args(argv)

    print(f"== fig4: GA convergence, {args.app} ==")
    for method in ("previous", "proposed"):
        cpu, res = convergence(args.app, method, args.seed, args.workers)
        rows = [
            (h.generation, cpu / h.best_time_s) for h in res.history
        ]
        dedup = max((h.dedup_ratio for h in res.history), default=0.0)
        print(f"\n[{method}] CPU-only {cpu:.1f}s; "
              f"final {res.best_time_s:.2f}s = {cpu/res.best_time_s:.1f}x "
              f"({res.evaluations} evals, {res.cache_hits} cache hits, "
              f"peak dedup {dedup:.0%}, search wall {res.wall_s:.1f}s)")
        print(ascii_plot(rows))
        print("csv:generation,speedup,gen_wall_s,hit_rate")
        for (g, s), h in zip(rows, res.history):
            print(f"csv:{g},{s:.3f},{h.gen_wall_s:.4f},{h.hit_rate:.3f}")


if __name__ == "__main__":
    main()
