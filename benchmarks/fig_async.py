"""Fast-search figure: vectorized batch pricing + steady-state GA.

Two sections, matching the two ``OffloadSpec.ga`` fast-search knobs
(docs/pipeline.md "Fast search"):

- **batch vs scalar pricing** — the same population priced through the
  scalar :class:`MixedEvaluator` loop and through
  :class:`BatchMixedEvaluator.evaluate_batch` at the default mixed sweep
  budget (population x generations genomes). The headline number is
  modeled-search throughput in genomes/sec; the verdict (and the exit
  code) keys on the headline program clearing a >= 10x speedup. Parity
  is asserted outright while we are at it — the batch path must agree
  with the scalar oracle to round-off on every genome it prices.
- **steady-state vs generational GA** — the same search budget on a
  latency-instrumented evaluator (a fixed sleep plus a deterministic
  straggler every Nth measurement, standing in for a verification-
  environment deploy+run) at several worker counts. The generational
  barrier pays the straggler once per generation across every lane; the
  steady loop pays it once per straggler. The evalpool's new ``idle_s``
  telemetry attributes exactly that difference.

  PYTHONPATH=src python -m benchmarks.fig_async
  PYTHONPATH=src python -m benchmarks.fig_async --smoke
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional, Tuple

import numpy as np

from benchmarks.common import add_common_args
from repro.core import ga
from repro.core import miniapps
from repro.core.evalpool import EvalPool
from repro.destinations import (
    BatchMixedEvaluator,
    MixedEvaluator,
    get_registry,
)
from repro.offload.spec import MIXED_BUDGET

HEADLINE = "hetero"
PROGRAMS = ("hetero", "himeno", "nasft")
SPEEDUP_BAR = 10.0
PARITY_RTOL = 1e-9  # the pipeline's verify re-measure tolerance


def _random_population(
    rng: np.random.Generator, gene_length: int, k: int, size: int
) -> List[Tuple[int, ...]]:
    return [
        tuple(int(x) for x in rng.integers(0, k, gene_length))
        for _ in range(size)
    ]


def _pricing_section(seed: int, repeats: int) -> float:
    """Scalar-vs-batch pricing on every miniapp; returns the headline
    program's speedup."""
    pop, gens = MIXED_BUDGET
    budget = pop * gens
    reg = get_registry("quadro-p4000")
    names = tuple(d.name for d in reg.destinations)
    print(f"\n== batch vs scalar pricing: {budget} genomes "
          f"({pop}x{gens} default mixed budget), quadro-p4000 ==")
    print("csv:program,genomes,scalar_gps,batch_gps,speedup,max_rel_err")
    headline_speedup = 0.0
    for pname in PROGRAMS:
        prog = miniapps.MINIAPPS[pname]()
        scalar = MixedEvaluator(prog, names, registry=reg)
        batch = BatchMixedEvaluator(prog, names, registry=reg)
        rng = np.random.default_rng(seed)
        genomes = _random_population(rng, prog.gene_length, scalar.k,
                                     budget)
        batch.evaluate_batch(genomes[:2])  # build tables off the clock
        t_scalar = min(
            _timed(lambda: [scalar(g) for g in genomes])
            for _ in range(repeats)
        )
        t_batch = min(
            _timed(lambda: batch.evaluate_batch(genomes))
            for _ in range(repeats)
        )
        # parity against the oracle, while both sets of numbers are hot
        bt = batch.evaluate_batch(genomes)
        st = [scalar(g) for g in genomes]
        err = max(
            abs(b - s) / max(abs(s), 1e-30) for b, s in zip(bt, st)
        )
        if err > PARITY_RTOL:
            raise AssertionError(
                f"{pname}: batch/scalar divergence {err:.2e} > "
                f"{PARITY_RTOL}"
            )
        gps_s, gps_b = budget / t_scalar, budget / t_batch
        speedup = t_scalar / t_batch
        if pname == HEADLINE:
            headline_speedup = speedup
        print(f"  {pname:8s}: scalar {gps_s:9.0f} g/s, "
              f"batch {gps_b:9.0f} g/s -> {speedup:5.1f}x "
              f"(parity {err:.1e})")
        print(f"csv:{pname},{budget},{gps_s:.0f},{gps_b:.0f},"
              f"{speedup:.2f},{err:.2e}")
    return headline_speedup


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _steady_section(seed: int, smoke: bool, max_workers: int) -> None:
    """Generational vs steady-state wall-clock under injected
    measurement latency with a deterministic straggler."""
    delay_s = 0.004 if smoke else 0.02
    straggle_every, straggle_x = 7, 5  # every 7th measurement is 5x slow
    prog = miniapps.himeno_program()
    reg = get_registry("quadro-p4000")
    names = tuple(d.name for d in reg.destinations)
    base = MixedEvaluator(prog, names, registry=reg)
    counter = {"n": 0}

    def slow_eval(genes):
        counter["n"] += 1
        mult = straggle_x if counter["n"] % straggle_every == 0 else 1
        time.sleep(delay_s * mult)
        return base(genes)

    n = prog.gene_length
    pop, gens = (8, 4) if smoke else (16, 8)
    print(f"\n== steady-state vs generational: {pop}x{gens} GA, "
          f"{delay_s * 1e3:.0f} ms/measurement, "
          f"every {straggle_every}th {straggle_x}x slow ==")
    print("csv:mode,workers,wall_s,idle_lane_s,evals,best_time_s")
    for workers in (4, max_workers) if max_workers > 4 else (4,):
        for steady in (False, True):
            params = ga.GAParams(
                population=pop, generations=gens, seed=seed,
                alleles=base.k, steady_state=steady,
            )
            counter["n"] = 0
            with EvalPool(slow_eval, workers=workers, batch=False) as pool:
                r = ga.run_ga(None, n, params, pool=pool)
                tot = pool.totals()
            mode = "steady" if steady else "generational"
            print(f"  {mode:12s} w={workers}: wall {r.wall_s:6.2f}s, "
                  f"idle {tot.idle_s:6.2f} lane-s, "
                  f"{tot.evaluated} measurements, "
                  f"best {r.best_time_s:.3f}s")
            print(f"csv:{mode},{workers},{r.wall_s:.3f},"
                  f"{tot.idle_s:.3f},{tot.evaluated},"
                  f"{r.best_time_s:.4f}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="fast-search figure: batch pricing + steady-state GA"
    )
    add_common_args(ap, cache=False)
    args = ap.parse_args(argv)

    repeats = 1 if args.smoke else 3
    speedup = _pricing_section(args.seed, repeats)
    _steady_section(args.seed, args.smoke, max(1, args.workers))

    ok = speedup >= SPEEDUP_BAR
    verdict = "PASS" if ok else "FAIL"
    print(f"\nverdict: {verdict} — {HEADLINE} batch pricing "
          f"{speedup:.1f}x vs scalar (bar {SPEEDUP_BAR:.0f}x)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
