"""Fig. 5 reproduction: previous method [33] vs this paper's proposals.

Paper table (measured on i5-7500 + Quadro P4000):
                     previous [33]   proposed
    Himeno benchmark      4.8x         15.4x
    NAS.FT                5.4x         10.0x

Both methods run the full GA (paper parameters) against the analytic
verification environment with the calibrated hardware model. ``--ablate``
adds the intermediate configurations that isolate each §3.3 improvement:
  directive expansion only / transfer reduction only / both (=proposed).
"""
from __future__ import annotations

import argparse
from typing import Dict, Tuple

from repro.core import evaluator as ev
from repro.core import evalpool as ep
from repro.core import ga, miniapps
from repro.core import transfer as tr

PAPER = {
    ("himeno", "previous"): 4.8,
    ("himeno", "proposed"): 15.4,
    ("nasft", "previous"): 5.4,
    ("nasft", "proposed"): 10.0,
}

CONFIGS: Dict[str, dict] = {
    # [33]: nest-level transfers, kernels directive only, no temp-area
    "previous": dict(mode=tr.TransferMode.NEST, staged=False,
                     kernels_only=True),
    # ablation: add the directive expansion, keep [33] transfers
    "dir-expansion-only": dict(mode=tr.TransferMode.NEST, staged=False,
                               kernels_only=False),
    # ablation: add bulk/present/temp-area transfers, keep kernels-only
    "transfer-only": dict(mode=tr.TransferMode.BULK, staged=True,
                          kernels_only=True),
    # this paper: both improvements
    "proposed": dict(mode=tr.TransferMode.BULK, staged=True,
                     kernels_only=False),
    # extra reference: [32]-era naive per-kernel sync
    "naive-2018": dict(mode=tr.TransferMode.NAIVE, staged=False,
                       kernels_only=True),
}


def run(app: str, config: str, seed: int = 0, workers: int = 1,
        cache_path: str = None) -> Tuple[float, float]:
    prog = miniapps.MINIAPPS[app]()
    n = prog.gene_length
    cpu = ev.predict_time(prog, (0,) * n).total_s
    kw = CONFIGS[config]
    e = ev.MiniappEvaluator(
        prog, kw["mode"], staged=kw["staged"], kernels_only=kw["kernels_only"]
    )
    cache = ep.FitnessCache(cache_path, fingerprint=e.fingerprint()) \
        if cache_path else None
    params = ga.GAParams.for_gene_length(n, seed=seed)
    try:
        with ep.EvalPool(e, workers=workers, cache=cache) as pool:
            res = ga.run_ga(None, n, params, pool=pool)
    finally:
        if cache is not None:
            cache.close()  # pools don't close caller-owned caches
    return cpu, cpu / res.best_time_s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ablate", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent fitness cache (JSONL, shared by all "
                         "app/config pairs; fingerprints keep them apart)")
    args = ap.parse_args(argv)

    configs = (
        ["previous", "proposed"]
        if not args.ablate
        else ["naive-2018", "previous", "dir-expansion-only",
              "transfer-only", "proposed"]
    )
    print("== fig5: performance improvement vs all-CPU ==")
    print(f"{'app':10s} {'config':20s} {'speedup':>8s} {'paper':>7s}")
    for app in ("himeno", "nasft"):  # the paper's table; `hetero` has its
        # own mixed-destination figure (fig_mixed_destinations.py)
        for config in configs:
            cpu, sp = run(app, config, args.seed, args.workers, args.cache)
            paper = PAPER.get((app, config))
            ptxt = f"{paper:.1f}x" if paper else "-"
            print(f"{app:10s} {config:20s} {sp:7.1f}x {ptxt:>7s}")
            print(f"csv:{app},{config},{sp:.2f},{paper or ''}")


if __name__ == "__main__":
    main()
