"""Fig. 5 reproduction: previous method [33] vs this paper's proposals.

Paper table (measured on i5-7500 + Quadro P4000):
                     previous [33]   proposed
    Himeno benchmark      4.8x         15.4x
    NAS.FT                5.4x         10.0x

Each (app, config) pair runs the full GA through the ``repro.offload``
facade (the method configurations live in ``repro.offload.METHODS``).
``--ablate`` adds the intermediate configurations that isolate each §3.3
improvement: directive expansion only / transfer reduction only / both
(=proposed).
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple

from benchmarks.common import add_common_args
from repro.offload import Offloader, OffloadSpec

PAPER = {
    ("himeno", "previous"): 4.8,
    ("himeno", "proposed"): 15.4,
    ("nasft", "previous"): 5.4,
    ("nasft", "proposed"): 10.0,
}


def run(app: str, config: str, seed: int = 0, workers: int = 1,
        cache_path: Optional[str] = None) -> Tuple[float, float]:
    spec = OffloadSpec(program=app, mode="binary", method=config,
                       seed=seed, workers=workers, cache=cache_path)
    res = Offloader(spec).run(until="search")
    return res.baseline_time_s, res.speedup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ablate", action="store_true")
    add_common_args(ap, smoke=False)
    args = ap.parse_args(argv)

    configs = (
        ["previous", "proposed"]
        if not args.ablate
        else ["naive-2018", "previous", "dir-expansion-only",
              "transfer-only", "proposed"]
    )
    print("== fig5: performance improvement vs all-CPU ==")
    print(f"{'app':10s} {'config':20s} {'speedup':>8s} {'paper':>7s}")
    for app in ("himeno", "nasft"):  # the paper's table; `hetero` has its
        # own mixed-destination figure (fig_mixed_destinations.py)
        for config in configs:
            cpu, sp = run(app, config, args.seed, args.workers, args.cache)
            paper = PAPER.get((app, config))
            ptxt = f"{paper:.1f}x" if paper else "-"
            print(f"{app:10s} {config:20s} {sp:7.1f}x {ptxt:>7s}")
            print(f"csv:{app},{config},{sp:.2f},{paper or ''}")


if __name__ == "__main__":
    main()
