"""Transfer-reduction ablation (paper §3.3): scheduled CPU-accelerator
traffic per mode, with all offloadable loops offloaded.

Shows the mechanism (bytes/events), complementing fig5's end-to-end times:
  naive  [32]: per-kernel-region sync, no residency
  nest   [33]: hoisted read-onlys + per-iteration flush of written arrays
  bulk  (new): whole-program residency ("data present" tracking)
and the temp-area effect (staged on/off) on compiler auto-transfers.

Hardware models come from the ``repro.offload`` registry (--hw), the
same one every pipeline spec resolves against.
"""
from __future__ import annotations

import argparse

from benchmarks.common import add_common_args
from repro.core import miniapps
from repro.core import transfer as tr
from repro.offload.programs import HW_MODELS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", default="quadro-p4000",
                    choices=sorted(HW_MODELS))
    add_common_args(ap, seed=False, workers=False, cache=False)
    args = ap.parse_args(argv)

    print("== transfer-reduction ablation (all offloadable loops on) ==")
    hw = HW_MODELS[args.hw]
    apps = ("himeno",) if args.smoke else ("himeno", "nasft")
    # the paper's §3.3 table apps; `hetero` has its own figure
    # (fig_mixed_destinations.py)
    for app in apps:
        prog = miniapps.MINIAPPS[app]()
        genes = (1,) * prog.gene_length
        print(f"\n[{app}] {prog.description}")
        hdr = (f"  {'mode':18s} {'h2d MB':>10s} {'d2h MB':>10s} "
               f"{'auto MB':>9s} {'events':>8s} {'xfer s':>8s}")
        print(hdr)
        for mode in (tr.TransferMode.NAIVE, tr.TransferMode.NEST,
                     tr.TransferMode.BULK):
            for staged in (False, True):
                s = tr.build_schedule(prog, genes, mode, staged=staged)
                t = s.total_bytes / hw.link_bw + s.total_events * hw.link_latency
                name = f"{mode.value}{'+temp-area' if staged else ''}"
                print(
                    f"  {name:18s} {s.h2d_bytes/1e6:10.1f} "
                    f"{s.d2h_bytes/1e6:10.1f} {s.auto_sync_bytes/1e6:9.1f} "
                    f"{s.total_events:8.0f} {t:8.3f}"
                )
                print(f"csv:{app},{name},{s.h2d_bytes:.0f},{s.d2h_bytes:.0f},"
                      f"{s.auto_sync_bytes:.0f},{s.total_events:.0f},{t:.4f}")


if __name__ == "__main__":
    main()
