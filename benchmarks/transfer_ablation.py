"""Transfer-reduction ablation (paper §3.3): scheduled CPU-accelerator
traffic per mode, with all offloadable loops offloaded.

Shows the mechanism (bytes/events), complementing fig5's end-to-end times:
  naive  [32]: per-kernel-region sync, no residency
  nest   [33]: hoisted read-onlys + per-iteration flush of written arrays
  bulk  (new): whole-program residency ("data present" tracking)
and the temp-area effect (staged on/off) on compiler auto-transfers.
"""
from __future__ import annotations

from repro.core import evaluator as ev
from repro.core import miniapps
from repro.core import transfer as tr


def main(argv=None):
    print("== transfer-reduction ablation (all offloadable loops on) ==")
    hw = ev.QUADRO_P4000
    for app in ("himeno", "nasft"):  # the paper's §3.3 table; `hetero`
        # has its own figure (fig_mixed_destinations.py)
        prog = miniapps.MINIAPPS[app]()
        genes = (1,) * prog.gene_length
        print(f"\n[{app}] {prog.description}")
        hdr = (f"  {'mode':18s} {'h2d MB':>10s} {'d2h MB':>10s} "
               f"{'auto MB':>9s} {'events':>8s} {'xfer s':>8s}")
        print(hdr)
        for mode in (tr.TransferMode.NAIVE, tr.TransferMode.NEST,
                     tr.TransferMode.BULK):
            for staged in (False, True):
                s = tr.build_schedule(prog, genes, mode, staged=staged)
                t = s.total_bytes / hw.link_bw + s.total_events * hw.link_latency
                name = f"{mode.value}{'+temp-area' if staged else ''}"
                print(
                    f"  {name:18s} {s.h2d_bytes/1e6:10.1f} "
                    f"{s.d2h_bytes/1e6:10.1f} {s.auto_sync_bytes/1e6:9.1f} "
                    f"{s.total_events:8.0f} {t:8.3f}"
                )
                print(f"csv:{app},{name},{s.h2d_bytes:.0f},{s.d2h_bytes:.0f},"
                      f"{s.auto_sync_bytes:.0f},{s.total_events:.0f},{t:.4f}")


if __name__ == "__main__":
    main()
