"""Shared CLI surface for the benchmark scripts.

Every benchmark takes the same evaluation-infrastructure flags
(--seed / --workers / --cache / --smoke); declaring them once here stops
the scripts drifting apart (each used to re-declare its own subset with
slightly different help text and defaults).
"""
from __future__ import annotations

import argparse


def add_common_args(
    ap: argparse.ArgumentParser,
    *,
    seed: bool = True,
    workers: bool = True,
    cache: bool = True,
    smoke: bool = True,
) -> argparse.ArgumentParser:
    """Add the shared benchmark flags; pass ``flag=False`` to omit one
    a script genuinely has no use for."""
    if seed:
        ap.add_argument("--seed", type=int, default=0,
                        help="GA RNG seed")
    if workers:
        ap.add_argument("--workers", type=int, default=1,
                        help="concurrent fitness measurements per "
                             "generation")
    if cache:
        ap.add_argument("--cache", default=None, metavar="PATH",
                        help="persistent fitness cache (JSONL); searches "
                             "with matching evaluator fingerprints share "
                             "measurements and killed runs resume warm")
    if smoke:
        ap.add_argument("--smoke", action="store_true",
                        help="small CI-sized budget (fast-tier smoke "
                             "invocation)")
    return ap
