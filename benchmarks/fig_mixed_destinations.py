"""Mixed-destination search figure (arXiv:2011.12431 direction).

Runs the offload GA on the heterogeneous pipeline miniapp over three
destination subsets of the modeled machine (host + Quadro P4000 + FPGA
card) through the ``repro.offload`` facade, and shows the headline
claim: one k-ary genome over ALL backends finds a placement strictly
faster than the best any single-backend search can reach, because the
app's loop classes favor different backends (tight stencils -> GPU,
sequential-carry scan stages -> FPGA pipelines, host-coupled control ->
CPU).

A second section demonstrates genome-aware seeding
(``OffloadSpec.warm_start``): the mixed initial population is warmed
with each single-destination best re-expressed in the k-ary alphabet,
which starts the search AT the best-single-destination level instead of
spending generations of paid measurements getting there
(measurements-to-parity is the win metric; both runs converge to the
mixed optimum).

All searches share one persistent fitness cache when ``--cache`` is
given: the mixed evaluator's fingerprint covers the machine, not the
searched subset, and its canonical cache keys are destination names — so
the CPU+GPU search (and the warm-start pre-searches) pre-pay
measurements the mixed search reuses.

  PYTHONPATH=src python -m benchmarks.fig_mixed_destinations
  PYTHONPATH=src python -m benchmarks.fig_mixed_destinations --smoke
  PYTHONPATH=src python -m benchmarks.fig_mixed_destinations \
      --cache /tmp/mixed.jsonl --workers 4
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence, Tuple

from benchmarks.common import add_common_args
from repro.offload import Offloader, OffloadSpec
from repro.offload.spec import MIXED_BUDGET, MIXED_SMOKE_BUDGET

SUBSETS: Tuple[Tuple[str, ...], ...] = (
    ("cpu", "gpu"),
    ("cpu", "fpga"),
    ("cpu", "gpu", "fpga"),
)


def search(subset: Sequence[str], population: int, generations: int,
           seed: int = 0, workers: int = 1,
           cache_path: Optional[str] = None, warm_start: bool = False):
    spec = OffloadSpec(
        program="hetero", mode="mixed", destinations=tuple(subset),
        population=population, generations=generations, seed=seed,
        workers=workers, cache=cache_path, warm_start=warm_start,
    )
    return Offloader(spec).run(until="search")


def gens_to_level(history, level: float) -> Optional[int]:
    """First generation whose best reaches ``level`` (None = never)."""
    for h in history:
        if h["best_time_s"] <= level * (1 + 1e-9):
            return h["generation"]
    return None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    args = ap.parse_args(argv)

    # the evaluator is analytic, so the paper-scale program costs the
    # same as a toy one — smoke only trims the GA budget (see the budget
    # constants' rationale in repro.offload.spec)
    pop, gens = MIXED_SMOKE_BUDGET if args.smoke else MIXED_BUDGET

    best_single = float("inf")
    mixed_best = float("inf")
    host_only = None
    results = {}
    for subset in SUBSETS:
        res = search(subset, pop, gens, args.seed, args.workers, args.cache)
        results[subset] = res
        if host_only is None:
            host_only = res.baseline_time_s
            prog_desc = res.stage("analyze").payload["description"]
            print(f"== mixed destinations: {prog_desc} ==")
            print(f"host-only (all-CPU): {host_only:.3f}s")
            print(f"{'destinations':18s} {'best_s':>9s} {'speedup':>8s} "
                  f"{'evals':>6s} {'hits':>5s}")
        p = res.stage("search").payload
        name = "+".join(subset)
        sp = host_only / res.best_time_s
        print(f"{name:18s} {res.best_time_s:9.4f} {sp:7.1f}x "
              f"{p['evaluations']:6d} {p['cache_hits']:5d}")
        print(f"csv:{name},{res.best_time_s:.5f},{sp:.2f},"
              f"{p['evaluations']},{p['cache_hits']}")
        if len(subset) < 3:
            best_single = min(best_single, res.best_time_s)
        else:
            mixed_best = res.best_time_s
            print("  mixed placement:")
            for loop, dest in p["placement"].items():
                if dest != "cpu":
                    print(f"    {loop:16s} -> {dest}")

    gain = best_single / mixed_best
    print(f"\nmixed vs best single destination: {gain:.2f}x "
          f"({'strictly faster' if mixed_best < best_single else 'NO GAIN'})")
    print(f"csv:mixed_vs_best_single,{gain:.4f}")

    # -- genome-aware seeding (OffloadSpec.warm_start) ----------------------
    # run the full-alphabet search cold vs warm at the full budget (the
    # analytic searches cost milliseconds; smoke keeps it too) and report
    # measurements-to-parity with the best single destination
    print("\n== warm-start convergence (genome-aware seeding) ==")
    cold = results[SUBSETS[-1]]
    warm = search(SUBSETS[-1], *MIXED_BUDGET, args.seed, args.workers,
                  args.cache, warm_start=True)
    wp = warm.stage("search").payload
    seed_info = warm.stage("seed").payload["seed_info"]
    print("single-destination seeds: "
          + ", ".join(f"{i['device']} {i['best_time_s']:.4f}s"
                      for i in seed_info))
    if cold.stage("search").payload["ga"]["generations"] != MIXED_BUDGET[1]:
        cold = search(SUBSETS[-1], *MIXED_BUDGET, args.seed, args.workers,
                      args.cache)
    cp = cold.stage("search").payload
    for tag, p in (("cold", cp), ("warm", wp)):
        g = gens_to_level(p["history"], best_single)
        evals_to = (g + 1) * p["ga"]["population"] if g is not None else None
        print(f"{tag}: gen0 best {p['history'][0]['best_time_s']:.4f}s; "
              f"reaches best-single level at gen "
              f"{'never' if g is None else g} "
              f"(~{evals_to or '-'} paid measurements); "
              f"final {p['best_time_s']:.4f}s")
        print(f"csv:warmstart,{tag},{p['history'][0]['best_time_s']:.5f},"
              f"{-1 if g is None else g},{p['best_time_s']:.5f}")


if __name__ == "__main__":
    main()
