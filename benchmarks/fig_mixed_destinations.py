"""Mixed-destination search figure (arXiv:2011.12431 direction).

Runs the offload GA on the heterogeneous pipeline miniapp over three
destination subsets of the modeled machine (host + Quadro P4000 + FPGA
card) and shows the headline claim: one k-ary genome over ALL backends
finds a placement strictly faster than the best any single-backend search
can reach, because the app's loop classes favor different backends
(tight stencils -> GPU, sequential-carry scan stages -> FPGA pipelines,
host-coupled control -> CPU).

All three searches share one persistent fitness cache when ``--cache`` is
given: the mixed evaluator's fingerprint covers the machine, not the
searched subset, and its canonical cache keys are destination names — so
the CPU+GPU search pre-pays measurements the mixed search reuses.

  PYTHONPATH=src python -m benchmarks.fig_mixed_destinations
  PYTHONPATH=src python -m benchmarks.fig_mixed_destinations --smoke
  PYTHONPATH=src python -m benchmarks.fig_mixed_destinations \
      --cache /tmp/mixed.jsonl --workers 4
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core import evalpool as ep
from repro.core import ga, miniapps
from repro.destinations import MixedEvaluator

SUBSETS: Tuple[Tuple[str, ...], ...] = (
    ("cpu", "gpu"),
    ("cpu", "fpga"),
    ("cpu", "gpu", "fpga"),
)


def search(
    subset: Sequence[str],
    prog,
    params: ga.GAParams,
    workers: int = 1,
    cache_path: Optional[str] = None,
) -> Tuple[ga.GAResult, MixedEvaluator, ep.GenTelemetry]:
    e = MixedEvaluator(prog, subset)
    params = dataclasses.replace(params, alleles=e.k)
    cache = ep.FitnessCache(cache_path, fingerprint=e.fingerprint()) \
        if cache_path else None
    try:
        with ep.EvalPool(e, workers=workers, cache=cache) as pool:
            res = ga.run_ga(None, prog.gene_length, params, pool=pool)
            tot = pool.totals()
    finally:
        if cache is not None:
            cache.close()  # pools don't close caller-owned caches
    return res, e, tot


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + short GA (CI fast-tier invocation)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent fitness cache shared by all three "
                         "searches (the mixed fingerprint is subset-"
                         "independent, so overlaps hit)")
    args = ap.parse_args(argv)

    # the evaluator is analytic, so the paper-scale program costs the same
    # as a toy one — smoke only trims the GA budget (the k=3 space needs
    # pop/gens ~24 to find the mixed optimum on every seed; the short
    # smoke GA still shows the win on the default seed)
    prog = miniapps.hetero_program()
    if args.smoke:
        params = ga.GAParams(population=10, generations=8, seed=args.seed,
                             timeout_s=1e6)
    else:
        params = ga.GAParams(population=24, generations=24, seed=args.seed,
                             timeout_s=1e6)

    host_only = MixedEvaluator(prog, ("cpu", "gpu")).host_only_time()
    print(f"== mixed destinations: {prog.description} ==")
    print(f"host-only (all-CPU): {host_only:.3f}s")
    print(f"{'destinations':18s} {'best_s':>9s} {'speedup':>8s} "
          f"{'evals':>6s} {'hits':>5s}")

    best_single = float("inf")
    mixed_best = float("inf")
    for subset in SUBSETS:
        res, e, tot = search(
            subset, prog, params, args.workers, args.cache
        )
        name = "+".join(subset)
        sp = host_only / res.best_time_s
        print(f"{name:18s} {res.best_time_s:9.4f} {sp:7.1f}x "
              f"{tot.evaluated:6d} {tot.cache_hits:5d}")
        print(f"csv:{name},{res.best_time_s:.5f},{sp:.2f},"
              f"{tot.evaluated},{tot.cache_hits}")
        if len(subset) < 3:
            best_single = min(best_single, res.best_time_s)
        else:
            mixed_best = res.best_time_s
            bd = e.breakdown(res.best_genes)
            print(f"  mixed plan: {bd.describe()}")
            for loop, dest in zip(
                prog.offloadable_loops,
                (e.dests[g].name for g in e.admissible(res.best_genes)),
            ):
                print(f"    {loop.name:16s} -> {dest}")

    gain = best_single / mixed_best
    print(f"\nmixed vs best single destination: {gain:.2f}x "
          f"({'strictly faster' if mixed_best < best_single else 'NO GAIN'})")
    print(f"csv:mixed_vs_best_single,{gain:.4f}")


if __name__ == "__main__":
    main()
