"""Capacity-aware residency figure: the hetero miniapp under a
constrained GPU.

The unbounded N-memory model assumes every offloaded loop's working set
fits on its accelerator. On the ``p4000-constrained`` machine registry —
the paper machine with a 45 MB GPU card and a slower-but-spacious 128 MB
FPGA card — that assumption is false for the hetero stencil pipeline
(three 16.8 MB planes per stencil), and this figure shows what that does
to the search:

1. **Divergence** — the winner of the UNBOUNDED search (hw
   ``quadro-p4000``), repriced with capacity-aware residency on the
   constrained machine, pays for GBs of per-frame streaming the
   unbounded model never priced: its claimed time and its achievable
   time split apart.

2. **Routing around thrashing** — the capacity-aware search (hw
   ``p4000-constrained``) prices eviction/streaming traffic inside the
   GA, so it finds a DIFFERENT winning placement (the stencils retreat
   to the spacious FPGA; verified the true optimum by exhaustive 3^12
   enumeration when the capacities were frozen) that is strictly faster
   than what the unbounded plan actually achieves on this machine.

3. **Report** — the pipeline's report stage states the winner's total
   eviction/streaming bytes under the machine's capacities.

4. **Second machine** — the same search on the ``tpu-v5e-host``
   registry (two fast devices with tight 64 MB memories) picks yet
   another placement: there, bounded thrash on one device beats paying
   cross-device hops, and the report prices the eviction traffic.

The searches are analytic (milliseconds each), so every section runs at
the full mixed budget even under ``--smoke`` — the CI-sized trim used by
other figures would make the GA's convergence, and therefore the
figure's claim, seed-lottery-dependent. ``--smoke`` is accepted for CLI
uniformity with the other figures.

  PYTHONPATH=src python -m benchmarks.fig_capacity
  PYTHONPATH=src python -m benchmarks.fig_capacity --smoke
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence, Tuple

from benchmarks.common import add_common_args
from repro.core import miniapps
from repro.destinations import MixedEvaluator, get_registry
from repro.offload import Offloader, OffloadSpec
from repro.offload.pipeline import render_report
from repro.offload.spec import MIXED_BUDGET


def search(hw: str, destinations: Tuple[str, ...], population: int,
           generations: int, seed: int = 0, workers: int = 1,
           cache_path: Optional[str] = None, warm_start: bool = True,
           until: str = "search"):
    spec = OffloadSpec(
        program="hetero", mode="mixed", hw=hw, destinations=destinations,
        population=population, generations=generations, seed=seed,
        workers=workers, cache=cache_path, warm_start=warm_start,
    )
    return Offloader(spec).run(until=until)


def _pressure(evaluator: MixedEvaluator, genes: Sequence[int]):
    bd = evaluator.breakdown(genes)
    s = bd.schedule
    return bd.total_s, s.total_evicted_bytes, s.total_spilled_bytes


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    args = ap.parse_args(argv)

    # full budget even under --smoke: see module docstring
    pop, gens = MIXED_BUDGET
    prog = miniapps.MINIAPPS["hetero"]()
    con_reg = get_registry("p4000-constrained")
    con_eval = MixedEvaluator(prog, ("cpu", "gpu", "fpga"),
                              registry=con_reg)
    caps = ", ".join(f"{d.name} {d.memory_bytes/1e6:.0f} MB"
                     for d in con_reg.destinations if d.bounded)
    print(f"== capacity-aware residency: {prog.description} ==")
    print(f"machine p4000-constrained: {caps} "
          "(rates and links identical to quadro-p4000)")

    # 1) the unbounded search's winner, repriced on the real card
    unb = search("quadro-p4000", ("cpu", "gpu", "fpga"), pop, gens,
                 args.seed, args.workers, args.cache)
    claimed = unb.best_time_s
    actual, evict_u, spill_u = _pressure(con_eval, unb.best_genes)
    print(f"\nunbounded search winner: claimed {claimed:.4f}s")
    print(f"  repriced with capacity-aware residency: {actual:.4f}s "
          f"({actual/claimed:.2f}x the claim) — evicted {evict_u/1e6:.0f} "
          f"MB, streamed {spill_u/1e6:.0f} MB per run")
    print(f"csv:unbounded,{claimed:.5f},{actual:.5f},"
          f"{evict_u:.0f},{spill_u:.0f}")

    # 2) the capacity-aware search on the same constrained machine
    con = search("p4000-constrained", ("cpu", "gpu", "fpga"), pop, gens,
                 args.seed, args.workers, args.cache, until="report")
    t_c, evict_c, spill_c = _pressure(con_eval, con.best_genes)
    print(f"\ncapacity-aware search winner: {t_c:.4f}s — evicted "
          f"{evict_c/1e6:.0f} MB, streamed {spill_c/1e6:.0f} MB")
    place_u = con_eval.placement(unb.best_genes)
    place_c = con_eval.placement(con.best_genes)
    changed = {l: (place_u[l], place_c[l]) for l in place_u
               if place_u[l] != place_c[l]}
    print(f"  placement changed for {len(changed)} loops:")
    for l, (a, b) in sorted(changed.items()):
        print(f"    {l:16s} {a} -> {b}")
    gain = actual / t_c
    print(f"  vs what the unbounded plan actually achieves here: "
          f"{gain:.2f}x "
          f"({'routed around thrashing' if t_c < actual else 'NO GAIN'})")
    print(f"csv:capacity_aware,{t_c:.5f},{evict_c:.0f},{spill_c:.0f},"
          f"{len(changed)},{gain:.4f}")

    # 3) the report stage states the eviction traffic
    print("\n-- offload report (capacity-aware run) --")
    print(render_report(con))

    # 4) second machine: same search, different placement
    tpu = search("tpu-v5e-host", ("cpu", "tpu0", "tpu1"), pop, gens,
                 args.seed, args.workers, args.cache)
    tp = tpu.stage("search").payload
    r = tp.get("residency", {})
    used = sorted(set(tp["placement"].values()) - {"cpu"})
    print(f"\n== second machine: tpu-v5e-host (2 devices, tight 64 MB "
          "each) ==")
    print(f"same search: best {tpu.best_time_s:.4f}s on {'+'.join(used)}; "
          f"evicted {r.get('evicted_bytes', 0.0)/1e6:.0f} MB "
          f"(bounded thrash beats cross-device hops on this machine)")
    print(f"csv:tpu,{tpu.best_time_s:.5f},"
          f"{r.get('evicted_bytes', 0.0):.0f},{'+'.join(used)}")


if __name__ == "__main__":
    main()
