"""Function-block substitution figure (docs/blocks.md; PAPERS.md:
arXiv:2004.09883 / 2005.04174).

The loop-level GA places every loop nest individually; function-block
offloading instead matches whole dataflow-chained loop groups against a
library of tuned kernels (``repro.kernels``) and lets the genome swap
the entire group for one library call. This figure shows the headline
claim on the heterogeneous pipeline miniapp: the best placement WITH
substitution is strictly faster than the best placement the loop-level
search can ever reach, because the fused library kernels avoid the
per-loop launch + intermediate traffic the loop-level placement must
pay.

Two comparisons, both at the same GA budget:

- **search vs search** — the blocks-on GA (loop genes + per-block
  substitution genes) against the blocks-off GA;
- **constructed** — the blocks-off *winner's* loop placement with only
  the substitution alleles enumerated on top, which isolates the
  substitution win from search luck: the verdict (and the exit code)
  keys on this deterministic genome strictly beating the loop-level
  best.

  PYTHONPATH=src python -m benchmarks.fig_blocks
  PYTHONPATH=src python -m benchmarks.fig_blocks --smoke
"""
from __future__ import annotations

import argparse
import itertools
import sys
from typing import Optional, Tuple

from benchmarks.common import add_common_args
from repro.offload import Offloader, OffloadSpec
from repro.offload.programs import resolve_adapter
from repro.offload.spec import MIXED_BUDGET, MIXED_SMOKE_BUDGET

PROGRAM = "hetero"


def _spec(blocks: bool, pop: int, gens: int, seed: int, workers: int,
          cache: Optional[str]) -> OffloadSpec:
    return OffloadSpec(
        program=PROGRAM, mode="mixed", blocks=blocks,
        population=pop, generations=gens, seed=seed, workers=workers,
        cache=cache, warm_start=True,
    )


def best_substitution_on(genes: Tuple[int, ...], evaluator):
    """The blocks-off winner's loop placement with the best substitution
    alleles enumerated on top: (time, full genome). Block gene 0 keeps
    every block at its loop-level placement, so this can never be worse
    than the loop-level winner under the same model."""
    n_loops = len(genes)
    m = evaluator.gene_length - n_loops
    k = evaluator.k
    best_t, best_g = float("inf"), None
    for block_genes in itertools.product(range(k), repeat=m):
        g = tuple(genes) + block_genes
        t = evaluator(g)
        if t < best_t:
            best_t, best_g = t, g
    return best_t, best_g


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    args = ap.parse_args(argv)
    pop, gens = MIXED_SMOKE_BUDGET if args.smoke else MIXED_BUDGET

    spec_off = _spec(False, pop, gens, args.seed, args.workers, args.cache)
    spec_on = _spec(True, pop, gens, args.seed, args.workers, args.cache)

    res_off = Offloader(spec_off).run(until="search")
    res_on = Offloader(spec_on).run(until="search")

    adapter = resolve_adapter(spec_on)
    evaluator = adapter.build_evaluator()
    host = res_off.baseline_time_s

    print(f"== function-block substitution: {PROGRAM} "
          f"(budget {pop}x{gens}) ==")
    print(f"host-only (all-CPU): {host:.3f}s")
    print("matched blocks:")
    for m in adapter.matches:
        print(f"  [{m.entry}] {'+'.join(m.loops)}")

    p_off = res_off.stage("search").payload
    p_on = res_on.stage("search").payload
    print(f"{'search':28s} {'best_s':>9s} {'speedup':>8s} {'evals':>6s}")
    for name, res, p in (("loop-level GA (blocks off)", res_off, p_off),
                         ("block-substitution GA", res_on, p_on)):
        sp = host / res.best_time_s
        print(f"{name:28s} {res.best_time_s:9.4f} {sp:7.1f}x "
              f"{p['evaluations']:6d}")
        print(f"csv:{name.split(' (')[0].replace(' ', '_')},"
              f"{res.best_time_s:.5f},{sp:.2f},{p['evaluations']}")

    subs = [s for s in (p_on.get("substitutions") or ()) if s["active"]]
    for s in subs:
        print(f"  GA winner substitutes [{s['entry']}] "
              f"{'+'.join(s['loops'])} -> {s['destination']}")

    # the deterministic verdict: substitution alleles on top of the
    # loop-level winner's own placement
    loop_best = res_off.best_time_s
    sub_t, sub_g = best_substitution_on(
        tuple(res_off.stage("search").payload["best_genes"]), evaluator
    )
    print(f"\nloop-level winner + best substitution alleles: {sub_t:.4f}s")
    for s in adapter.substitutions(sub_g) or ():
        if s["active"]:
            print(f"  [{s['entry']}] {'+'.join(s['loops'])} -> "
                  f"{s['destination']}")
    gain = loop_best / sub_t
    verdict = "strictly faster" if sub_t < loop_best else "NO GAIN"
    print(f"substitution vs loop-level best: {gain:.2f}x ({verdict})")
    print(f"csv:substitution_vs_loop_level,{gain:.4f}")
    return 0 if sub_t < loop_best else 1


if __name__ == "__main__":
    sys.exit(main())
